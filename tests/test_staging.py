"""Zero-copy host staging tests (DESIGN.md §16): StagingPool
semantics and the aliasing rule, per-stage wall-time accounting
(`Pipeline.stage_stats()`), bit-exactness of every zero-copy path
against its legacy copying twin (chunk / flatten / pack257 / CRC /
store put-get-repair via the ``staging_enabled`` A/B flag), and the
machine-aware pipeline-depth default."""
import os
import threading

import numpy as np
import pytest

from repro.core import gf
from repro.core.circulant import CodeSpec
from repro.codes import (CodeClass, FAMILY_PRODUCT_MATRIX, make_code)
from repro.exec import staging
from repro.exec.pipeline import Pipeline
from repro.exec.plan import PlanCache
from repro.exec.staging import POOL_BUCKET_MIN, STAGE_NAMES, StagingPool
from repro.kernels import dispatch
from repro.store import CodedObjectStore
from repro.store.object_store import share_crc
from repro.store.stripes import StripeManager

P = 257
SPEC4 = CodeSpec.make(4, P)
rng = np.random.default_rng(16)


def make_store(staging_on=True, spec=SPEC4, **kw):
    st = CodedObjectStore(spec, n_nodes=12, stripe_symbols=64, **kw)
    st.staging_enabled = staging_on
    return st


def payload_bytes(size, seed=0):
    return np.random.default_rng(seed).integers(
        0, 256, size, dtype=np.uint8).tobytes()


# ------------------------------------------------------------ staging pool
class TestStagingPool:
    def test_miss_then_hit_reuses_same_base(self):
        pool = StagingPool()
        a = pool.acquire((3, 100))
        base = StagingPool._base_of(a)
        pool.release(a)
        b = pool.acquire((300,))        # same bucket, different shape
        assert StagingPool._base_of(b) is base
        s = pool.stats()
        assert (s.hits, s.misses, s.released, s.in_use) == (1, 1, 1, 1)

    def test_bucket_ladder_floor_and_growth(self):
        pool = StagingPool()
        small = StagingPool._base_of(pool.acquire((8,)))
        assert small.size == POOL_BUCKET_MIN
        big = StagingPool._base_of(pool.acquire((POOL_BUCKET_MIN + 1,)))
        assert big.size == POOL_BUCKET_MIN * 2

    def test_unreleased_buffer_never_reissued(self):
        # the aliasing rule: pool depth grows on demand, so concurrent
        # acquires (>= any pipeline depth) all get distinct backing
        pool = StagingPool()
        views = [pool.acquire((64,)) for _ in range(4)]
        bases = {id(StagingPool._base_of(v)) for v in views}
        assert len(bases) == 4
        assert pool.stats().in_use == 4

    def test_double_and_foreign_release_are_noops(self):
        pool = StagingPool()
        a = pool.acquire((16,))
        pool.release(a)
        pool.release(a)                       # double release
        pool.release(np.zeros(16, np.int32))  # never issued
        pool.release("not an array")
        s = pool.stats()
        assert s.released == 1 and s.in_use == 0
        # the freed buffer is pooled exactly once, not twice
        b1 = pool.acquire((16,))
        b2 = pool.acquire((16,))
        assert StagingPool._base_of(b1) is not StagingPool._base_of(b2)

    def test_max_pooled_cap_drops_excess(self):
        pool = StagingPool(max_pooled=1)
        a, b = pool.acquire((8,)), pool.acquire((8,))
        pool.release(a)
        pool.release(b)
        assert pool.stats().pooled_bytes == POOL_BUCKET_MIN * 4  # one int32 buf

    def test_dtype_slots_are_separate(self):
        pool = StagingPool()
        a = pool.acquire((32,), np.int32)
        pool.release(a)
        b = pool.acquire((32,), np.uint8)
        assert b.dtype == np.uint8
        assert StagingPool._base_of(b) is not StagingPool._base_of(a)

    def test_clear_resets_everything(self):
        pool = StagingPool()
        pool.release(pool.acquire((8,)))
        pool.clear()
        s = pool.stats()
        assert s == (0, 0, 0, 0, 0)


# ------------------------------------------------- aliasing: planner pads
class TestPlannerStagingAliasing:
    def _pc(self):
        return PlanCache(dispatch.get("jnp-int32"), P, bucket_min=32)

    def test_pad_buffer_held_until_host_then_recycled(self):
        pc = self._pc()
        mat = rng.integers(0, P, (4, 8)).astype(np.int32)
        blocks = rng.integers(0, P, (8, 33)).astype(np.int32)  # odd -> pad
        res = pc.matmul(mat, blocks)
        assert pc.staging.stats().in_use > 0      # staged pad in flight
        out = res.host()
        assert pc.staging.stats().in_use == 0     # released at host()
        np.testing.assert_array_equal(
            out, (mat.astype(np.int64) @ blocks) % P)

    def test_scribbling_reused_buffer_never_alters_results(self):
        # the caller-visible aliasing guarantee: once host() returned,
        # the pooled pad buffer may be reused and scribbled freely
        # without disturbing any previously materialized result
        pc = self._pc()
        mat = rng.integers(0, P, (4, 8)).astype(np.int32)
        blocks = rng.integers(0, P, (8, 41)).astype(np.int32)
        ref = (mat.astype(np.int64) @ blocks) % P
        out = pc.matmul(mat, blocks).host()
        reused = pc.staging.acquire((8, 64))      # same bucket as the pad
        reused[...] = 12345
        np.testing.assert_array_equal(out, ref)


# ------------------------------------------------------ stage accounting
class TestStageStats:
    def test_pipeline_reports_all_stage_clocks(self):
        pipe = Pipeline(io_workers=1, depth=2)
        pipe.reset_stage_stats()
        pipe.map(list(range(4)),
                 read=lambda i: i,
                 compute=lambda i, d: d * 2,
                 consume=lambda i, out: None)
        stats = pipe.stage_stats()
        assert set(STAGE_NAMES) <= set(stats)
        assert all(stats[k] >= 0.0 for k in STAGE_NAMES)
        assert stats["t_stage_read"] > 0.0
        assert stats["t_dispatch"] > 0.0
        pipe.close()

    def test_pack_clock_counts_staging_writes(self):
        pipe = Pipeline(io_workers=1, depth=1)
        pipe.reset_stage_stats()
        out = np.empty(1 << 12, np.int32)
        gf.bytes_to_symbols_into(payload_bytes(1000), out)
        assert pipe.stage_stats()["t_pack"] > 0.0
        pipe.reset_stage_stats()
        assert pipe.stage_stats()["t_pack"] == 0.0
        pipe.close()

    def test_reset_rebases_process_clock_not_other_pipelines(self):
        # stage clocks are deltas of the process-wide accumulator: one
        # pipeline's reset must not erase another's view
        a, b = Pipeline(io_workers=1), Pipeline(io_workers=1)
        a.reset_stage_stats()
        b.reset_stage_stats()
        staging.record_stage("pack", 0.5)
        a.reset_stage_stats()
        assert a.stage_stats()["t_pack"] == 0.0
        assert b.stage_stats()["t_pack"] == pytest.approx(0.5)
        a.close(); b.close()


# ------------------------------------------------- zero-copy bit-exactness
class TestZeroCopyBitExact:
    @pytest.mark.parametrize("nbytes", [0, 1, 63, 64, 65, 1000])
    def test_bytes_to_symbols_into_matches_pad_chain(self, nbytes):
        data = payload_bytes(nbytes, seed=nbytes)
        cap = 4 * 256
        out = np.full(cap, -1, np.int32)
        gf.bytes_to_symbols_into(data, out)
        ref = np.pad(gf.bytes_to_symbols(data), (0, cap - nbytes))
        np.testing.assert_array_equal(out, ref)

    def test_bytes_to_symbols_into_validates(self):
        with pytest.raises(ValueError):
            gf.bytes_to_symbols_into(b"x" * 10, np.empty(4, np.int32))
        with pytest.raises(ValueError):
            gf.bytes_to_symbols_into(b"x", np.empty(4, np.int64))

    @pytest.mark.parametrize("nbytes", [0, 1, 511, 512, 513, 5000])
    def test_chunk_one_pass_matches_legacy(self, nbytes):
        sm = StripeManager(SPEC4, CodedObjectStore(
            SPEC4, n_nodes=12, stripe_symbols=64).stripes.layout,
            stripe_symbols=64)
        data = payload_bytes(nbytes, seed=nbytes)
        fast, map_f = sm.chunk(data, one_pass=True)
        slow, map_s = sm.chunk(data, one_pass=False)
        assert map_f == map_s
        np.testing.assert_array_equal(fast, slow)

    def test_flatten_out_matches_fresh(self):
        sm = StripeManager(SPEC4, CodedObjectStore(
            SPEC4, n_nodes=12, stripe_symbols=64).stripes.layout,
            stripe_symbols=64)
        blocks = rng.integers(0, P, (3, SPEC4.n, 64)).astype(np.int32)
        ref = sm.flatten(blocks)
        out = np.empty((SPEC4.n, 3 * 64), np.int32)
        assert sm.flatten(blocks, out=out) is out
        np.testing.assert_array_equal(out, ref)

    @pytest.mark.parametrize("s", [1, 31, 64])
    def test_pack257_rows_out_matches_fresh(self, s):
        sym = rng.integers(0, 257, (6, s)).astype(np.int32)
        sym[0, 0] = 256                       # force the wrap case
        low_ref, his_ref = gf.pack257_rows(sym)
        buf = np.empty(sym.shape, np.uint8)
        low, his = gf.pack257_rows(sym, out=buf)
        assert low is buf
        np.testing.assert_array_equal(low, low_ref)
        for h, hr in zip(his, his_ref):
            np.testing.assert_array_equal(h, hr)
        # roundtrip through the out= expansion path too
        exp = np.empty(sym.shape, np.int32)
        assert gf.unpack257_rows(low, his, out=exp) is exp
        np.testing.assert_array_equal(exp, sym)

    def test_share_crc_zero_copy_matches_legacy(self):
        for seed in range(4):
            r = np.random.default_rng(seed)
            a = r.integers(0, 256, 97).astype(np.int32)
            red = r.integers(0, 257, 97).astype(np.int32)
            red[seed] = 256                   # cover the 256 wrap
            assert share_crc(a, red, zero_copy=True) == \
                share_crc(a, red, zero_copy=False)


# --------------------------------------------- store A/B: staged vs legacy
class TestStoreStagingAB:
    def test_put_get_bit_exact_and_crcs_identical(self):
        data = payload_bytes(3000, seed=3)
        st_on, st_off = make_store(True), make_store(False)
        st_on.put("obj", data)
        st_off.put("obj", data)
        assert st_on.get("obj") == data
        assert st_off.get("obj") == data
        # the zero-copy CRC chain must land in the SAME integrity ledger
        assert st_on._stats["obj"].share_crcs == \
            st_off._stats["obj"].share_crcs

    def test_degraded_get_and_repair_bit_exact(self):
        from repro.store import RepairScheduler
        data = payload_bytes(4096, seed=5)
        for staging_on in (True, False):
            st = make_store(staging_on)
            sched = RepairScheduler(st)
            st.subscribe(sched.on_event)
            st.put("obj", data)
            st.fail_node(1)
            assert st.get("obj") == data       # degraded read
            sched.drain_all()
            assert st.get("obj") == data and st.verify()

    def test_view_installs_keep_shares_independent(self):
        # staged installs store VIEWS into the per-put block arrays;
        # the drills corrupt shares in place ([1][0] ^= 0x55), so a
        # mutation through one share must never leak into another
        st = make_store(True)
        data = payload_bytes(2048, seed=7)
        st.put("obj", data)
        shares = [sh for node in st._shares
                  for (key, _t), sh in node.items() if key == "obj"]
        assert len(shares) >= 2
        before = [np.array(sh[1], copy=True) for sh in shares[1:]]
        shares[0][1][0] ^= 0x55               # scribble one share's data
        for sh, ref in zip(shares[1:], before):
            np.testing.assert_array_equal(np.asarray(sh[1]), ref)


# ----------------------------------------- batched PM regeneration parity
class TestBatchedRegenParity:
    def test_regenerate_many_planned_matches_per_plan(self):
        cc = CodeClass(FAMILY_PRODUCT_MATRIX, n=5, k=2, d=3)
        code = make_code(cc)
        assert code.supports_batched_regen()
        plans = [code.repair_plan(node) for node in (1, 3, 5, 2)]
        assert all(p is not None for p in plans)
        s = 37
        sends = rng.integers(0, P, (len(plans), plans[0].d, s),
                             dtype=np.int64).astype(np.int32)
        batched = code.regenerate_many_planned(plans, sends).host()
        for i, plan in enumerate(plans):
            np.testing.assert_array_equal(
                batched[i], code.regenerate(plan, sends[i]))

    def test_shape_validation(self):
        cc = CodeClass(FAMILY_PRODUCT_MATRIX, n=4, k=2, d=2)
        code = make_code(cc)
        plan = code.repair_plan(1)
        with pytest.raises(ValueError):
            code.regenerate_many_planned([plan], np.zeros((2, 2, 8), np.int32))


# ------------------------------------------------- machine-aware defaults
class TestPipelineDepthDefault:
    def test_store_auto_depth_matches_machine(self):
        st = CodedObjectStore(SPEC4, n_nodes=12, stripe_symbols=64)
        want = 2 if (os.cpu_count() or 1) >= 2 else 1
        assert st.pipeline.depth == want

    def test_explicit_depth_honored(self):
        st = CodedObjectStore(SPEC4, n_nodes=12, stripe_symbols=64,
                              pipeline_depth=1)
        assert st.pipeline.depth == 1

    def test_install_inline_at_depth_1_pooled_above(self):
        # depth 1 must stay a true serial baseline: installs run on the
        # calling thread, never through the pool
        st1 = CodedObjectStore(SPEC4, n_nodes=12, stripe_symbols=64,
                               pipeline_depth=1)
        st2 = CodedObjectStore(SPEC4, n_nodes=12, stripe_symbols=64,
                               pipeline_depth=2)
        ran_on = []
        st1._install(lambda: ran_on.append(threading.get_ident()))
        assert ran_on == [threading.get_ident()]
        st2._install(lambda: ran_on.append(threading.get_ident()))
        st2.pipeline.barrier()
        assert len(ran_on) == 2 and ran_on[1] != threading.get_ident()
