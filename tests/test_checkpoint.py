"""MSR checkpointing: roundtrips, failure paths, byte accounting (gamma)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.circulant import CodeSpec
from repro.checkpoint.msr_checkpoint import MSRCheckpointer


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (37, 19), jnp.float32),
                   "b": jnp.arange(11, dtype=jnp.int32)},
        "opt": {"mu": jax.random.normal(k, (37, 19), jnp.float32) * 1e-3,
                "step": jnp.asarray(7, jnp.int32)},
    }


def assert_state_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture
def ckpt(tmp_path):
    return MSRCheckpointer(tmp_path, CodeSpec.make(4, 257))


def test_save_restore_systematic(ckpt):
    state = make_state()
    ckpt.save(3, state)
    got, report = ckpt.restore(state, 3)
    assert_state_equal(got, state)
    assert report.path == "systematic"
    # systematic restore reads only the n data blocks = ~B bytes
    n, s = ckpt.spec.n, None
    assert report.bytes_read <= report.bytes_total_stored // 2 + 64


def test_restore_latest_step(ckpt):
    s1, s2 = make_state(1), make_state(2)
    ckpt.save(1, s1)
    ckpt.save(2, s2)
    got, rep = ckpt.restore(s1)
    assert rep.step == 2
    assert_state_equal(got, s2)


def test_single_failure_regeneration_gamma(ckpt):
    """The paper's headline: repairing one node reads (k+1)/(2k) of B."""
    state = make_state()
    ckpt.save(5, state)
    got, report = ckpt.restore(state, 5, failed_nodes=[3])
    assert_state_equal(got, state)
    assert report.path == "regenerate"
    assert report.repaired_nodes == (3,)
    # repair-only bandwidth (isolated):
    b = ckpt.repair_node(5, 2)
    k = ckpt.spec.k
    n = ckpt.spec.n
    manifest_block = report.bytes_total_stored // (2 * n)   # ~S bytes
    ideal = (k + 1) * manifest_block
    assert b <= ideal * 1.10, (b, ideal)       # within 10% (packing overhead)
    assert b < 2 * k * manifest_block * 0.75   # strictly better than B


def test_multi_failure_reconstruction(ckpt):
    state = make_state()
    ckpt.save(1, state)
    got, report = ckpt.restore(state, 1, failed_nodes=[1, 4, 6])
    assert_state_equal(got, state)
    assert report.path == "reconstruct"
    assert set(report.repaired_nodes) == {1, 4, 6}
    # repaired files are valid: a fresh systematic restore succeeds
    got2, rep2 = ckpt.restore(state, 1)
    assert rep2.path == "systematic"
    assert_state_equal(got2, state)


def test_unrecoverable_raises(ckpt):
    state = make_state()
    ckpt.save(1, state)
    with pytest.raises(RuntimeError):
        ckpt.restore(state, 1, failed_nodes=[1, 2, 3, 4, 5])


@pytest.mark.parametrize("n_failed", [2, 3, 4])   # k=4, n=8: up to n-k
def test_multi_failure_repair_and_rewrite(ckpt, n_failed):
    """2..n-k failures: one decode matmul rebuilds data AND every lost
    pair; the repaired files are physically rewritten (newcomer protocol)."""
    state = make_state(n_failed)
    ckpt.save(1, state)
    failed = list(range(2, 2 + n_failed))
    # dead hosts: their files are gone, not just ignored
    for f in failed:
        for path in ckpt._node_files(1, f):
            path.unlink()
    got, report = ckpt.restore(state, 1, failed_nodes=failed)
    assert_state_equal(got, state)
    assert report.path == "reconstruct"
    assert report.repaired_nodes == tuple(failed)
    for f in failed:
        for path in ckpt._node_files(1, f):
            assert path.exists()
    # the rewritten step is fully consistent again
    assert ckpt.scrub(1).clean
    got2, rep2 = ckpt.restore(state, 1)
    assert rep2.path == "systematic"
    assert_state_equal(got2, state)


def test_multi_failure_no_repair(ckpt):
    """repair=False: degraded read only — state comes back, nothing is
    rewritten."""
    state = make_state(9)
    ckpt.save(1, state)
    failed = [3, 7]
    for f in failed:
        for path in ckpt._node_files(1, f):
            path.unlink()
    got, report = ckpt.restore(state, 1, failed_nodes=failed, repair=False)
    assert_state_equal(got, state)
    assert report.path == "reconstruct"
    assert report.repaired_nodes == ()
    for f in failed:
        for path in ckpt._node_files(1, f):
            assert not path.exists()


def test_scrub_clean_then_flags_corruption(ckpt):
    state = make_state(11)
    ckpt.save(1, state)
    report = ckpt.scrub(1)
    assert report.clean and report.mismatched_nodes == ()
    assert report.nodes_checked == ckpt.spec.n
    # scrub reads every pair: ~2B bytes (within packing overhead)
    _, rep = ckpt.restore(state, 1)
    assert report.bytes_read >= 2 * rep.bytes_read
    # flip one symbol of node 5's redundancy block on disk
    from repro.core import gf
    _, rf = ckpt._node_files(1, 5)
    z = np.load(rf)
    r = gf.unpack257(z["low"], z["hi"])
    r[0] = (r[0] + 1) % 257
    low, hi = gf.pack257(r)
    np.savez(rf, low=low, hi=hi)
    report2 = ckpt.scrub(1)
    assert not report2.clean
    assert 5 in report2.mismatched_nodes
    # the flagged node is repairable in place; scrub comes back clean
    ckpt.repair_node(1, 5)
    assert ckpt.scrub(1).clean


def test_every_single_node_repairable(tmp_path):
    spec = CodeSpec.make(3, 257)
    ckpt = MSRCheckpointer(tmp_path, spec)
    state = make_state(4)
    ckpt.save(2, state)
    for node in range(1, spec.n + 1):
        got, report = ckpt.restore(state, 2, failed_nodes=[node])
        assert_state_equal(got, state)
        assert report.path == "regenerate"


def test_gc_keeps_last(tmp_path):
    ckpt = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257), keep_last=2)
    state = make_state()
    for s in (1, 2, 3, 4):
        ckpt.save(s, state)
    assert ckpt.steps() == [3, 4]


def test_bit_exact_across_dtypes(tmp_path):
    """bf16/f32/int mixtures survive the byte<->symbol mapping exactly."""
    ckpt = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257))
    state = {"a": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
             "b": jnp.asarray([3.14159e-8, 1e30], jnp.float32),
             "c": jnp.asarray([-5, 2**30], jnp.int32)}
    ckpt.save(1, state)
    got, _ = ckpt.restore(state, 1, failed_nodes=[2])
    assert_state_equal(got, state)


# ------------------------------------------- crash consistency (DESIGN.md §12)
from repro.io import (FaultInjector, FaultyBlob, GiveUpError, LocalBlob,
                      count_tmp_orphans, fast_retry)


class TestCrashConsistency:
    def test_steps_ignores_uncommitted(self, ckpt, tmp_path):
        ckpt.save(1, make_state())
        # orphans a crashed writer could leave: a staging dir and a
        # manifest-less (torn, pre-protocol) generation
        (tmp_path / "step_000002.tmp").mkdir()
        (tmp_path / "step_000003").mkdir()
        (tmp_path / "step_000003" / "node_01.a.npy").write_bytes(b"x")
        assert ckpt.steps() == [1]
        got, rep = ckpt.restore(make_state())       # latest = committed latest
        assert rep.step == 1

    def test_recover_sweeps_orphans(self, ckpt, tmp_path):
        ckpt.save(1, make_state())
        (tmp_path / "step_000002.tmp").mkdir()
        (tmp_path / "step_000002.tmp" / "junk").write_bytes(b"x")
        (tmp_path / "step_000003").mkdir()
        d1 = ckpt._step_dir(1)
        (d1 / "node_01.a.npy.tmp").write_bytes(b"x")   # torn atomic rewrite
        removed = ckpt.recover()
        assert set(removed) == {"step_000002.tmp", "step_000003",
                                "step_000001/node_01.a.npy.tmp"}
        assert count_tmp_orphans(tmp_path) == 0
        assert not (tmp_path / "step_000003").exists()
        assert ckpt.steps() == [1]
        assert ckpt.scrub(1).clean                     # committed gen intact

    def test_recover_runs_at_construction(self, tmp_path):
        (tmp_path / "step_000009.tmp").mkdir()
        ck = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257))
        assert count_tmp_orphans(tmp_path) == 0

    def test_manifest_carries_content_crcs(self, ckpt):
        import json
        m = ckpt.save(4, make_state())
        n = ckpt.spec.n
        assert len(m["crc"]) == 2 * n
        on_disk = json.loads(
            (ckpt._step_dir(4) / "manifest.json").read_text())
        assert on_disk["crc"] == m["crc"]
        # repair rewrites are bit-exact: CRCs stay valid, no manifest churn
        ckpt.repair_node(4, 1)
        assert ckpt.scrub(4).clean

    def test_save_heals_transient_faults(self, tmp_path):
        faults = FaultInjector(seed=0)
        faults.add(op="write", kind="transient", times=3)
        ck = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257),
                             io_backend=FaultyBlob(LocalBlob(), faults),
                             retry=fast_retry())
        state = make_state()
        ck.save(1, state)
        got, _ = ck.restore(state, 1)
        assert_state_equal(got, state)
        assert ck.retry_stats.retries >= 3 and ck.retry_stats.giveups == 0

    def test_persistent_fault_gives_up_leaves_no_generation(self, tmp_path):
        faults = FaultInjector(seed=0)
        faults.add(op="write", match="step_000002", kind="transient")
        ck = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257),
                             io_backend=FaultyBlob(LocalBlob(), faults),
                             retry=fast_retry())
        state = make_state()
        ck.save(1, state)
        with pytest.raises(GiveUpError):
            ck.save(2, state)
        assert ck.steps() == [1]
        assert count_tmp_orphans(tmp_path) == 0
        got, _ = ck.restore(state)                  # previous gen still good
        assert_state_equal(got, state)

    def test_overwrite_same_step_is_atomic(self, ckpt):
        s1, s2 = make_state(1), make_state(2)
        ckpt.save(1, s1)
        ckpt.save(1, s2)                            # park-old + commit path
        assert ckpt.steps() == [1]
        got, _ = ckpt.restore(s1, 1)
        assert_state_equal(got, s2)
        assert ckpt.scrub(1).clean


class TestWriteBehind:
    def test_save_async_roundtrip_and_barrier(self, ckpt):
        state = make_state()
        fut = ckpt.save_async(7, state)
        manifest = ckpt.barrier()
        assert manifest["step"] == 7 and fut.done()
        assert ckpt.barrier() is None               # idempotent
        got, _ = ckpt.restore(state, 7)
        assert_state_equal(got, state)
        ckpt.close()

    def test_snapshot_isolates_from_mutation(self, ckpt):
        """The write-behind snapshot must capture the state AT CALL TIME:
        host-side mutation after save_async (the donation stand-in) must
        not leak into the checkpoint."""
        state = {"w": np.arange(64, dtype=np.int32)}
        want = state["w"].copy()
        ckpt.save_async(1, state)
        state["w"] += 999                           # "donated"/reused buffer
        ckpt.barrier()
        got, _ = ckpt.restore({"w": want}, 1)
        np.testing.assert_array_equal(np.asarray(got["w"]), want)
        ckpt.close()

    def test_single_inflight(self, ckpt):
        """A second save_async fences the first: generations commit in
        order, never interleaved."""
        for s in (1, 2, 3):
            ckpt.save_async(s, make_state(s))
        ckpt.barrier()
        assert ckpt.steps() == [1, 2, 3]
        got, _ = ckpt.restore(make_state(), 3)
        assert_state_equal(got, make_state(3))
        ckpt.close()

    def test_failure_surfaces_at_barrier(self, tmp_path):
        faults = FaultInjector(seed=0)
        faults.add(op="write", match="step_000002", kind="transient")
        ck = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257),
                             io_backend=FaultyBlob(LocalBlob(), faults),
                             retry=fast_retry())
        ck.save_async(2, make_state())
        with pytest.raises(GiveUpError):
            ck.barrier()
        assert ck.steps() == []
        ck.close()
