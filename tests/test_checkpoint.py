"""MSR checkpointing: roundtrips, failure paths, byte accounting (gamma)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.circulant import CodeSpec
from repro.checkpoint.msr_checkpoint import MSRCheckpointer


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (37, 19), jnp.float32),
                   "b": jnp.arange(11, dtype=jnp.int32)},
        "opt": {"mu": jax.random.normal(k, (37, 19), jnp.float32) * 1e-3,
                "step": jnp.asarray(7, jnp.int32)},
    }


def assert_state_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture
def ckpt(tmp_path):
    return MSRCheckpointer(tmp_path, CodeSpec.make(4, 257))


def test_save_restore_systematic(ckpt):
    state = make_state()
    ckpt.save(3, state)
    got, report = ckpt.restore(state, 3)
    assert_state_equal(got, state)
    assert report.path == "systematic"
    # systematic restore reads only the n data blocks = ~B bytes
    n, s = ckpt.spec.n, None
    assert report.bytes_read <= report.bytes_total_stored // 2 + 64


def test_restore_latest_step(ckpt):
    s1, s2 = make_state(1), make_state(2)
    ckpt.save(1, s1)
    ckpt.save(2, s2)
    got, rep = ckpt.restore(s1)
    assert rep.step == 2
    assert_state_equal(got, s2)


def test_single_failure_regeneration_gamma(ckpt):
    """The paper's headline: repairing one node reads (k+1)/(2k) of B."""
    state = make_state()
    ckpt.save(5, state)
    got, report = ckpt.restore(state, 5, failed_nodes=[3])
    assert_state_equal(got, state)
    assert report.path == "regenerate"
    assert report.repaired_nodes == (3,)
    # repair-only bandwidth (isolated):
    b = ckpt.repair_node(5, 2)
    k = ckpt.spec.k
    n = ckpt.spec.n
    manifest_block = report.bytes_total_stored // (2 * n)   # ~S bytes
    ideal = (k + 1) * manifest_block
    assert b <= ideal * 1.10, (b, ideal)       # within 10% (packing overhead)
    assert b < 2 * k * manifest_block * 0.75   # strictly better than B


def test_multi_failure_reconstruction(ckpt):
    state = make_state()
    ckpt.save(1, state)
    got, report = ckpt.restore(state, 1, failed_nodes=[1, 4, 6])
    assert_state_equal(got, state)
    assert report.path == "reconstruct"
    assert set(report.repaired_nodes) == {1, 4, 6}
    # repaired files are valid: a fresh systematic restore succeeds
    got2, rep2 = ckpt.restore(state, 1)
    assert rep2.path == "systematic"
    assert_state_equal(got2, state)


def test_unrecoverable_raises(ckpt):
    state = make_state()
    ckpt.save(1, state)
    with pytest.raises(RuntimeError):
        ckpt.restore(state, 1, failed_nodes=[1, 2, 3, 4, 5])


@pytest.mark.parametrize("n_failed", [2, 3, 4])   # k=4, n=8: up to n-k
def test_multi_failure_repair_and_rewrite(ckpt, n_failed):
    """2..n-k failures: one decode matmul rebuilds data AND every lost
    pair; the repaired files are physically rewritten (newcomer protocol)."""
    state = make_state(n_failed)
    ckpt.save(1, state)
    failed = list(range(2, 2 + n_failed))
    # dead hosts: their files are gone, not just ignored
    for f in failed:
        for path in ckpt._node_files(1, f):
            path.unlink()
    got, report = ckpt.restore(state, 1, failed_nodes=failed)
    assert_state_equal(got, state)
    assert report.path == "reconstruct"
    assert report.repaired_nodes == tuple(failed)
    for f in failed:
        for path in ckpt._node_files(1, f):
            assert path.exists()
    # the rewritten step is fully consistent again
    assert ckpt.scrub(1).clean
    got2, rep2 = ckpt.restore(state, 1)
    assert rep2.path == "systematic"
    assert_state_equal(got2, state)


def test_multi_failure_no_repair(ckpt):
    """repair=False: degraded read only — state comes back, nothing is
    rewritten."""
    state = make_state(9)
    ckpt.save(1, state)
    failed = [3, 7]
    for f in failed:
        for path in ckpt._node_files(1, f):
            path.unlink()
    got, report = ckpt.restore(state, 1, failed_nodes=failed, repair=False)
    assert_state_equal(got, state)
    assert report.path == "reconstruct"
    assert report.repaired_nodes == ()
    for f in failed:
        for path in ckpt._node_files(1, f):
            assert not path.exists()


def test_scrub_clean_then_flags_corruption(ckpt):
    state = make_state(11)
    ckpt.save(1, state)
    report = ckpt.scrub(1)
    assert report.clean and report.mismatched_nodes == ()
    assert report.nodes_checked == ckpt.spec.n
    # scrub reads every pair: ~2B bytes (within packing overhead)
    _, rep = ckpt.restore(state, 1)
    assert report.bytes_read >= 2 * rep.bytes_read
    # flip one symbol of node 5's redundancy block on disk
    from repro.core import gf
    _, rf = ckpt._node_files(1, 5)
    z = np.load(rf)
    r = gf.unpack257(z["low"], z["hi"])
    r[0] = (r[0] + 1) % 257
    low, hi = gf.pack257(r)
    np.savez(rf, low=low, hi=hi)
    report2 = ckpt.scrub(1)
    assert not report2.clean
    assert 5 in report2.mismatched_nodes
    # the flagged node is repairable in place; scrub comes back clean
    ckpt.repair_node(1, 5)
    assert ckpt.scrub(1).clean


def test_every_single_node_repairable(tmp_path):
    spec = CodeSpec.make(3, 257)
    ckpt = MSRCheckpointer(tmp_path, spec)
    state = make_state(4)
    ckpt.save(2, state)
    for node in range(1, spec.n + 1):
        got, report = ckpt.restore(state, 2, failed_nodes=[node])
        assert_state_equal(got, state)
        assert report.path == "regenerate"


def test_gc_keeps_last(tmp_path):
    ckpt = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257), keep_last=2)
    state = make_state()
    for s in (1, 2, 3, 4):
        ckpt.save(s, state)
    assert ckpt.steps() == [3, 4]


def test_bit_exact_across_dtypes(tmp_path):
    """bf16/f32/int mixtures survive the byte<->symbol mapping exactly."""
    ckpt = MSRCheckpointer(tmp_path, CodeSpec.make(2, 257))
    state = {"a": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
             "b": jnp.asarray([3.14159e-8, 1e30], jnp.float32),
             "c": jnp.asarray([-5, 2**30], jnp.int32)}
    ckpt.save(1, state)
    got, _ = ckpt.restore(state, 1, failed_nodes=[2])
    assert_state_equal(got, state)
