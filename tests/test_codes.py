"""Property suite for the pluggable code-family subsystem (DESIGN.md §15):
both registered families run through ONE generic battery — systematic
map, bit-exact reconstruction from every k-subset, regeneration parity
at the cut-set bound gamma = d*S*B/(k(d-k+1)), and per-family cache
isolation for overlapping (k, p) parameters.
"""
import itertools
import json

import numpy as np
import pytest

from repro.codes import (CodeClass, FAMILY_DOUBLE_CIRCULANT,
                         FAMILY_PRODUCT_MATRIX, default_code_class,
                         families, make_code)
from repro.core.circulant import CodeSpec
from repro.core.repair import decode_cache_stats

from tests._hypothesis_compat import given, settings, st

S = 7           # symbols per block — small keeps the k-subset sweeps fast

GRID = [
    CodeClass(FAMILY_DOUBLE_CIRCULANT, n=4, k=2, d=3),
    CodeClass(FAMILY_DOUBLE_CIRCULANT, n=6, k=3, d=4),
    CodeClass(FAMILY_PRODUCT_MATRIX, n=4, k=2, d=2),     # d = 2k-2 floor
    CodeClass(FAMILY_PRODUCT_MATRIX, n=5, k=2, d=3),     # d < n-1
    CodeClass(FAMILY_PRODUCT_MATRIX, n=6, k=3, d=4),     # d < n-1
    CodeClass(FAMILY_PRODUCT_MATRIX, n=7, k=3, d=5),
]
_IDS = [cc.key() for cc in GRID]
_CODES: dict = {}


def code_for(cc: CodeClass):
    """One live code per class for the whole module (PM construction
    solves a nullspace; no need to redo it per test)."""
    if cc not in _CODES:
        _CODES[cc] = make_code(cc)
    return _CODES[cc]


def payload(cc: CodeClass, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed * 1000 + cc.n * 10 + cc.d)
    code = code_for(cc)
    return rng.integers(0, cc.p, (code.data_blocks, S),
                        dtype=np.int64).astype(np.int32)


def stacked_downloads(code, shares, subset) -> np.ndarray:
    """(k*q, S) download matrix in the family's helper_block_ids order."""
    return np.stack([shares[j - 1][b]
                     for j, b in code.helper_block_ids(subset)])


# ------------------------------------------------------------- registry
def test_both_families_registered():
    fams = families()
    assert FAMILY_DOUBLE_CIRCULANT in fams
    assert FAMILY_PRODUCT_MATRIX in fams


def test_code_class_meta_roundtrip_and_key_uniqueness():
    keys = set()
    for cc in GRID:
        assert CodeClass.from_meta(cc.to_meta()) == cc
        keys.add(cc.key())
    assert len(keys) == len(GRID)


def test_default_code_class_is_double_circulant():
    spec = CodeSpec.make(3, 257)
    cc = default_code_class(spec)
    assert cc.family == FAMILY_DOUBLE_CIRCULANT
    assert (cc.n, cc.k, cc.d, cc.p) == (spec.n, spec.k, spec.k + 1, spec.p)


def test_code_class_validation():
    with pytest.raises(ValueError):
        CodeClass("x", n=4, k=4, d=4)           # k >= n
    with pytest.raises(ValueError):
        CodeClass("x", n=4, k=2, d=4)           # d > n-1
    with pytest.raises(KeyError, match="unknown code family"):
        make_code(CodeClass("no-such-family", n=4, k=2, d=3))


# ------------------------------------------------- geometry + systematic map
@pytest.mark.parametrize("cc", GRID, ids=_IDS)
def test_msr_geometry_and_systematic_map(cc):
    code = code_for(cc)
    q = code.share_blocks
    assert q == cc.d - cc.k + 1
    assert code.data_blocks == cc.k * q
    data = payload(cc)
    shares = code.encode_shares(data)
    assert shares.shape == (cc.n, q, S)
    for m in range(code.data_blocks):
        node, b = code.data_location(m)
        np.testing.assert_array_equal(shares[node - 1][b], data[m])


@pytest.mark.parametrize("cc", GRID, ids=_IDS)
def test_reconstruct_every_k_subset_bit_exact(cc):
    code = code_for(cc)
    data = payload(cc)
    shares = code.encode_shares(data)
    for subset in itertools.combinations(range(1, cc.n + 1), cc.k):
        got = code.reconstruct(subset, stacked_downloads(code, shares,
                                                         subset))
        np.testing.assert_array_equal(got, data)


# ----------------------------------------------------------- regeneration
@pytest.mark.parametrize("cc", GRID, ids=_IDS)
def test_regenerate_every_node_at_cut_set_bound(cc):
    code = code_for(cc)
    data = payload(cc)
    shares = code.encode_shares(data)
    B = code.data_blocks * S
    for f in range(1, cc.n + 1):
        plan = code.repair_plan(f)
        assert plan is not None
        assert f not in plan.helpers and len(plan.helpers) == cc.d
        sends = np.stack([code.helper_send(sm, shares[h - 1])
                          for h, sm in zip(plan.helpers,
                                           plan.send_matrices)])
        # each helper sends beta = 1 block: gamma = d*S symbols, which
        # is exactly the MSR cut-set point d*S*B / (k (d-k+1) * S) ...
        measured = sends.size
        assert measured == cc.d * S
        assert measured == cc.d * B // (cc.k * (cc.d - cc.k + 1))
        assert measured == code.gamma_regenerate_symbols(S)
        rebuilt = code.regenerate(plan, sends)
        np.testing.assert_array_equal(rebuilt, shares[f - 1])


@pytest.mark.parametrize("cc", [cc for cc in GRID
                                if cc.family == FAMILY_PRODUCT_MATRIX
                                and cc.d < cc.n - 1],
                         ids=lambda cc: cc.key())
def test_product_matrix_repairs_with_restricted_helpers(cc):
    """d < n-1: regeneration must work from ANY d-subset of survivors,
    not just a fixed embedded set."""
    code = code_for(cc)
    data = payload(cc, seed=3)
    shares = code.encode_shares(data)
    others = [j for j in range(1, cc.n + 1) if j != 1]
    for pool in itertools.combinations(others, cc.d):
        plan = code.repair_plan(1, available=pool)
        assert plan is not None and set(plan.helpers) <= set(pool)
        sends = np.stack([code.helper_send(sm, shares[h - 1])
                          for h, sm in zip(plan.helpers,
                                           plan.send_matrices)])
        np.testing.assert_array_equal(code.regenerate(plan, sends),
                                      shares[0])


def test_double_circulant_requires_embedded_helpers():
    """The DC family's repair is determined: prev + k next nodes.  A
    pool missing any embedded helper yields no plan (the store falls
    back to full decode) — never a wrong plan."""
    cc = GRID[0]
    code = code_for(cc)
    plan = code.repair_plan(1)
    assert plan is not None
    missing = plan.helpers[0]
    pool = tuple(j for j in range(2, cc.n + 1) if j != missing)
    assert code.repair_plan(1, available=pool) is None


@pytest.mark.parametrize("cc", GRID, ids=_IDS)
def test_repair_plan_none_when_too_few_available(cc):
    code = code_for(cc)
    pool = tuple(range(2, 2 + cc.d - 1))     # d-1 survivors only
    assert code.repair_plan(1, available=pool) is None


# --------------------------------------------------------- multi-loss rows
@pytest.mark.parametrize("cc", GRID, ids=_IDS)
def test_share_rows_rebuild_lost_nodes(cc):
    code = code_for(cc)
    data = payload(cc, seed=5)
    shares = code.encode_shares(data)
    lost = [1, cc.n]
    use = tuple(range(2, 2 + cc.k))
    mat = code.share_rows(use, lost)
    out = (np.asarray(mat, np.int64)
           @ stacked_downloads(code, shares, use).astype(np.int64)) % cc.p
    q = code.share_blocks
    for i, f in enumerate(lost):
        np.testing.assert_array_equal(out[i * q:(i + 1) * q],
                                      shares[f - 1])


# -------------------------------------------------------- property battery
@settings(max_examples=12, deadline=None)
@given(idx=st.integers(min_value=0, max_value=len(GRID) - 1),
       seed=st.integers(min_value=0, max_value=2**20))
def test_property_random_subset_roundtrip(idx, seed):
    """Random class x random payload x random k-subset: reconstruct is
    bit-exact and regeneration moves exactly gamma = d*S symbols."""
    cc = GRID[idx]
    code = code_for(cc)
    rng = np.random.default_rng(seed)
    data = rng.integers(0, cc.p, (code.data_blocks, S),
                        dtype=np.int64).astype(np.int32)
    shares = code.encode_shares(data)
    subset = tuple(sorted(rng.choice(np.arange(1, cc.n + 1), size=cc.k,
                                     replace=False).tolist()))
    got = code.reconstruct(subset, stacked_downloads(code, shares, subset))
    np.testing.assert_array_equal(got, data)
    f = int(rng.integers(1, cc.n + 1))
    plan = code.repair_plan(f)
    assert plan is not None
    sends = np.stack([code.helper_send(sm, shares[h - 1])
                      for h, sm in zip(plan.helpers, plan.send_matrices)])
    assert sends.size == code.gamma_regenerate_symbols(S)
    np.testing.assert_array_equal(code.regenerate(plan, sends),
                                  shares[f - 1])


# -------------------------------------------------- per-family cache identity
def test_overlapping_parameters_use_distinct_cache_families():
    """DC(n4,k2) and PM(n4,k2,d2) share (k, p) and overlapping subsets;
    their decode inverses must land in separately-keyed cache families
    (the satellite fix: no cross-family collisions in shared caches)."""
    dc = code_for(GRID[0])
    pm = code_for(GRID[2])
    data_dc = payload(GRID[0], seed=7)
    data_pm = payload(GRID[2], seed=7)
    sh_dc = dc.encode_shares(data_dc)
    sh_pm = pm.encode_shares(data_pm)
    for subset in itertools.combinations(range(1, 5), 2):
        np.testing.assert_array_equal(
            dc.reconstruct(subset, stacked_downloads(dc, sh_dc, subset)),
            data_dc)
        np.testing.assert_array_equal(
            pm.reconstruct(subset, stacked_downloads(pm, sh_pm, subset)),
            data_pm)
    stats = decode_cache_stats()
    dc_fams = [f for f in stats if f.startswith("double-circulant[n4,k2")]
    pm_fams = [f for f in stats if f == pm.family_key()]
    assert dc_fams and pm_fams
    assert set(dc_fams).isdisjoint(pm_fams)
    assert all(stats[f].misses > 0 for f in pm_fams)


# ------------------------------------------------------- report integration
def test_bench_report_codes_headline_and_skip_rows(tmp_path, monkeypatch):
    """report.py --bench: the codes row renders from BENCH_codes.json,
    and every expected-but-absent trajectory file gets an explicit
    skip-with-notice row instead of silently vanishing."""
    from benchmarks import report
    rec = {"frontier": [{"family": "product-matrix", "n": 6, "k": 3,
                         "d": 4, "repair_ratio_vs_rs": 0.6667}],
           "conversion": {"mbps": 5.0, "bit_exact": True, "orphans": 0}}
    (tmp_path / "BENCH_codes.json").write_text(json.dumps(rec))
    monkeypatch.setattr(report, "REPO_ROOT", tmp_path)
    table = report.bench_table()
    assert "1 classes on frontier" in table
    assert "product-matrix n6k3d4" in table
    for stem in report.EXPECTED_BENCH:
        if stem != "BENCH_codes":
            assert f"`{stem}.json` | (missing" in table
