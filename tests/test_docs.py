"""Documentation health under pytest (mirrors the CI docs job).

`tools/check_docs.py` is the single source of truth; these tests import
its checks so a stale DESIGN.md anchor, a dead markdown link or a broken
README quickstart fails tier-1 locally, not just in the docs job.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "tools"))

import check_docs


def test_design_section_refs_resolve():
    assert check_docs.check_section_refs() == []


def test_markdown_relative_links_exist():
    assert check_docs.check_relative_links() == []


def test_design_has_cluster_section():
    assert "9" in check_docs.design_headings()


def test_readme_quickstart_runs():
    assert check_docs.run_readme_doctest() == []
