"""Smoke-run every example end to end under pytest (ISSUE 3 satellite).

Each example is executed as a subprocess exactly the way the README
documents it (``PYTHONPATH=src python examples/...``) with reduced sizes
so the whole battery stays in CI smoke budget.  The examples assert
their own bit-exactness internally; here we only require a clean exit
and the expected ledger lines on stdout.
"""
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
EXAMPLES = REPO / "examples"


def run_example(name: str, *args: str, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(EXAMPLES / name), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (
        f"{name} failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    return proc.stdout


def test_quickstart_example():
    out = run_example("quickstart.py", "--k", "2", "--mb", "0.25")
    assert "regenerated BIT-EXACTLY in one fused matmul" in out
    assert "decode-inverse cache: 1 hit / 1 miss" in out


def test_serve_demo_kill_nodes_while_serving():
    out = run_example("serve_demo.py", "--batch", "2", "--new-tokens", "4")
    assert "BIT-EXACTLY" in out
    assert "[repair] rebuilt" in out
    assert "availability=1.0" in out


def test_train_tiny_lm_crash_recovery():
    out = run_example("train_tiny_lm.py", "--steps", "9")
    assert "repair event(s)" in out
    assert "BIT-EXACT equal" in out


def test_store_demo_rack_failure_and_drain():
    out = run_example("store_demo.py", "--objects", "4", "--object-kb", "24")
    # every get during and after the rack failure is bit-exact
    assert "[degraded]" in out and "BIT-EXACT" in out
    assert "[healed]" in out
    # the queue re-prioritizes: at-risk stripes repaired first
    assert "scheduler repairs at-risk stripes first" in out
    # repair traffic beats the classical-RS re-download baseline
    assert "ratio" in out and "[scheduler] drained" in out
    assert "/ 0 failed" in out             # nothing went unserved
