"""Coded object store: stripe-manager roundtrips, degraded reads up to
the full n - k erasure budget, scheduler priority/coalescing/throttling,
and the store-backed checkpointer (DESIGN.md §10)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.msr_checkpoint import MSRCheckpointer
from repro.cluster.events import single_node_loss
from repro.cluster.simulator import ClusterSimulator
from repro.core import placement
from repro.core.circulant import CodeSpec
from repro.store import CodedObjectStore, RepairScheduler
from repro.store.stripes import StripeManager

SPEC2 = CodeSpec.make(2, 257)
SPEC4 = CodeSpec.make(4, 257)


def make_store(spec=SPEC4, n_nodes=12, stripe_symbols=64, **kw):
    return CodedObjectStore(spec, n_nodes=n_nodes,
                            stripe_symbols=stripe_symbols, **kw)


def payload_bytes(size, seed=0):
    return np.random.default_rng(seed).integers(
        0, 256, size, dtype=np.uint8).tobytes()


# ------------------------------------------------------------- stripe manager
class TestStripeManager:
    def test_chunk_assemble_roundtrip_odd_sizes(self):
        sm = StripeManager(SPEC4, placement.rack_layout(12, 4),
                           stripe_symbols=16)
        for size in (0, 1, 2, 15, 16 * SPEC4.n, 16 * SPEC4.n + 1, 1000):
            data = payload_bytes(size, seed=size)
            blocks, smap = sm.chunk(data)
            assert blocks.shape[1:] == (SPEC4.n, 16)
            assert smap.n_stripes >= 1
            assert sm.assemble(blocks, smap) == data

    def test_multi_stripe_encode_matches_per_stripe(self):
        sm = StripeManager(SPEC4, placement.rack_layout(8, 2),
                           stripe_symbols=32)
        blocks, _ = sm.chunk(payload_bytes(4000))
        red = sm.encode(blocks)
        for t in range(blocks.shape[0]):       # one-matmul == stripe-by-stripe
            ref = np.asarray(sm.code.encode(blocks[t]), np.int32)
            assert np.array_equal(red[t], ref)

    def test_placement_rotates_and_respects_racks(self):
        layout = placement.rack_layout(12, 4)
        sm = StripeManager(SPEC4, layout, stripe_symbols=8)
        pls = {sm.placement(t) for t in range(12)}
        assert len(pls) == 12                  # stripes spread over the ring
        for pl in pls:
            assert len(set(pl)) == SPEC4.n     # distinct physical nodes
            assert placement.max_shares_per_rack(layout, pl) \
                <= SPEC4.n - SPEC4.k

    def test_unsafe_layout_rejected(self):
        # one rack holding everything can never survive its own loss
        layout = placement.rack_layout(8, 1)
        with pytest.raises(ValueError, match="layout unsafe"):
            StripeManager(SPEC4, layout, stripe_symbols=8)

    @settings(max_examples=15, deadline=None)
    @given(st.binary(min_size=0, max_size=3000), st.sampled_from([2, 4]))
    def test_property_roundtrip(self, data, k):
        spec = SPEC2 if k == 2 else SPEC4
        store = make_store(spec, n_nodes=2 * spec.n, stripe_symbols=16)
        store.put("x", data)
        assert store.get("x") == data


# ---------------------------------------------------------------- object store
class TestObjectStore:
    def test_put_get_delete_stat(self):
        store = make_store()
        data = payload_bytes(1000)
        stat = store.put("a", data)
        assert stat.size_bytes == 1000 and stat.n_stripes >= 1
        assert store.get("a") == data
        assert store.stat("a").key == "a"
        assert store.keys() == ["a"]
        store.delete("a")
        with pytest.raises(KeyError):
            store.get("a")
        with pytest.raises(KeyError):
            store.stat("a")
        assert store.keys() == []

    def test_zero_length_object(self):
        store = make_store()
        store.put("empty", b"")
        assert store.get("empty") == b""
        assert store.stat("empty").n_stripes == 1   # still owns a footprint

    def test_array_object_roundtrip(self):
        store = make_store()
        arr = np.random.default_rng(1).standard_normal((13, 7)).astype(
            np.float32)
        store.put("arr", arr)
        out = store.get("arr")
        assert out.dtype == arr.dtype and out.shape == arr.shape
        assert np.array_equal(out, arr)

    def test_overwrite_replaces(self):
        store = make_store()
        store.put("x", b"old contents")
        store.put("x", b"new")
        assert store.get("x") == b"new"

    def test_object_spanning_many_stripes(self):
        store = make_store(stripe_symbols=32)
        data = payload_bytes(32 * SPEC4.n * 9 + 17)     # 10 stripes
        store.put("big", data)
        assert store.stat("big").n_stripes == 10
        assert store.get("big") == data

    @pytest.mark.parametrize("losses", [1, 2, 3, 4])   # up to n - k
    def test_get_under_failure_every_loss_count(self, losses):
        # n_nodes == n: every stripe loses a share per failed node, so
        # `losses` failures put every stripe exactly `losses` under
        store = make_store(n_nodes=SPEC4.n, stripe_symbols=32)
        data = payload_bytes(5000)
        store.put("x", data)
        for v in range(1, losses + 1):
            store.fail_node(v)
        res = store.get_ext("x")
        assert res.obj == data
        assert res.degraded_stripes == store.stat("x").n_stripes

    def test_beyond_budget_raises(self):
        store = make_store(n_nodes=SPEC4.n, stripe_symbols=32)
        store.put("x", payload_bytes(100))
        for v in range(1, SPEC4.n - SPEC4.k + 2):      # n - k + 1 losses
            store.fail_node(v)
        with pytest.raises(RuntimeError, match="data loss"):
            store.get("x")

    def test_degraded_read_batches_one_matmul_per_pattern(self, monkeypatch):
        # 16 stripes on an 8-node ring: the rotating placement maps the
        # failed physical node to 8 distinct failure patterns, each
        # covering 2 stripes -> 8 decode matmuls and 8 cached inverses
        # for 16 degraded stripes (one per pattern, NOT one per stripe)
        store = make_store(n_nodes=SPEC4.n, stripe_symbols=16)
        store.put("x", payload_bytes(16 * SPEC4.n * 16))  # 16 stripes
        store.fail_node(2)
        store.code.repair.decode_cache.clear()
        calls = []
        orig = store.code.repair.apply_planned
        monkeypatch.setattr(store.code.repair, "apply_planned",
                            lambda *a: calls.append(1) or orig(*a))
        res = store.get_ext("x")
        info = store.code.repair.decode_cache.cache_info()
        assert res.degraded_stripes == 16
        assert len(calls) == SPEC4.n       # one matmul per pattern
        # helper subsets collide across patterns (every missing node >= 5
        # decodes from {1,2,3,4}), so the inverse cache solves even fewer
        assert info.misses == 5 and info.hits + info.misses == SPEC4.n

    @pytest.mark.parametrize("n_nodes", [8, 9, 10, 11, 13])
    def test_default_racks_safe_on_any_ring_size(self, n_nodes):
        # the default rack count must survive rotating-window wrap on
        # rings that are not a multiple of the rack count (odd sizes)
        store = make_store(n_nodes=n_nodes, stripe_symbols=16)
        data = payload_bytes(700)
        store.put("x", data)
        assert store.get("x") == data

    def test_put_to_failed_node_is_lost_at_birth(self):
        store = make_store(n_nodes=SPEC4.n, stripe_symbols=16)
        store.fail_node(3)
        data = payload_bytes(300)
        store.put("x", data)
        assert store.get("x") == data                   # degrades around it
        assert store.total_lost_shares() == store.stat("x").n_stripes

    def test_verify_catches_tampering(self):
        store = make_store(stripe_symbols=16)
        store.put("x", payload_bytes(200))
        assert store.verify()
        for shares in store._shares:
            for share in shares.values():
                share[1][0] = (share[1][0] + 1) % 257
                assert not store.verify()
                return


# ------------------------------------------------------------------ scheduler
class TestScheduler:
    def _wired(self, **kw):
        store = make_store(**kw)
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        return store, sched

    def test_priority_orders_at_risk_first(self):
        store, sched = self._wired(stripe_symbols=32)
        store.put("x", payload_bytes(32 * SPEC4.n * 11))
        store.fail_node(1)
        store.fail_node(2)       # stripes on both nodes are closer to loss
        order = sched.peek_order()
        rems = [rem for _, _, rem in order]
        assert rems == sorted(rems)
        assert rems[0] < rems[-1]          # genuinely mixed priorities
        # drain respects the same order: the first repaired stripes are
        # exactly the at-risk set
        at_risk = {(key, t) for key, t, rem in order if rem == rems[0]}
        budget = len(at_risk) * 2 * store.k * store.S
        sched.drain(budget_symbols=budget)
        for key, t in at_risk:
            assert store.lost_code_nodes(key, t) == ()

    def test_priority_updates_on_second_failure(self):
        store, sched = self._wired(stripe_symbols=32)
        store.put("x", payload_bytes(32 * SPEC4.n * 11))
        store.fail_node(1)
        first = sched.peek_order()[0][2]
        store.fail_node(2)
        assert sched.peek_order()[0][2] < first

    def test_single_failure_coalesces_into_one_batch_call(self, monkeypatch):
        store, sched = self._wired(stripe_symbols=32)
        data = payload_bytes(32 * SPEC4.n * 7)
        store.put("x", data)
        store.fail_node(4)
        assert sched.pending() > 1
        calls = []
        orig = store.code.repair.regenerate_batch_planned
        monkeypatch.setattr(store.code.repair, "regenerate_batch_planned",
                            lambda *a, **k: calls.append(1) or orig(*a, **k))
        rep = sched.drain_all()
        assert len(calls) == 1 and rep.batch_calls == 1
        assert rep.decode_calls == 0
        assert rep.ticks == 1
        assert store.get("x") == data and store.verify()
        # embedded repair: (k+1)S per share vs 2kS RS baseline
        assert rep.ratio_vs_rs == pytest.approx(
            (store.k + 1) / (2 * store.k))

    def test_multi_loss_uses_full_decode(self):
        store, sched = self._wired(n_nodes=SPEC4.n, stripe_symbols=32)
        data = payload_bytes(3000)
        store.put("x", data)
        store.fail_node(1)
        store.fail_node(2)       # every stripe loses 2 shares
        rep = sched.drain_all()
        assert rep.batch_calls == 0 and rep.decode_calls > 0
        assert rep.ratio_vs_rs == pytest.approx(0.5)   # 2kS vs 2*2kS
        assert store.get("x") == data and store.verify()

    def test_bandwidth_budget_throttles(self):
        store, sched = self._wired(stripe_symbols=32)
        store.put("x", payload_bytes(32 * SPEC4.n * 11))
        store.fail_node(1)
        pending = sched.pending()
        assert pending > 2
        cost = (store.k + 1) * store.S            # embedded repair each
        rep1 = sched.drain(budget_symbols=2 * cost)
        assert rep1.repaired_stripes == 2
        assert rep1.remaining == pending - 2
        total = sched.drain_all(budget_symbols=2 * cost)
        assert total.ticks == -(-rep1.remaining // 2)
        assert sched.pending() == 0 and store.verify()

    def test_drain_time_scales_with_budget(self):
        # the simulated drain time must reflect the throttle: half the
        # budget -> twice the ticks -> ~twice the simulated seconds
        times = {}
        for budget_stripes in (1, 2):
            store, sched = self._wired(stripe_symbols=32)
            store.put("x", payload_bytes(32 * SPEC4.n * 11))
            store.fail_node(1)
            budget = budget_stripes * (store.k + 1) * store.S
            times[budget_stripes] = sched.drain_all(budget_symbols=budget)
        t1, t2 = times[1].drain_time_s, times[2].drain_time_s
        assert t1 > t2 > 0
        assert t1 == pytest.approx(2 * t2, rel=0.2)

    def test_budget_never_stalls_below_one_task(self):
        store, sched = self._wired(stripe_symbols=32)
        store.put("x", payload_bytes(200))
        store.fail_node(1)
        rep = sched.drain_all(budget_symbols=1)   # < one repair's cost
        assert rep.repaired_stripes >= 1 and sched.pending() == 0

    def test_zero_budget_clamped_not_crashing(self):
        store, sched = self._wired(stripe_symbols=32)
        store.put("x", payload_bytes(200))
        store.fail_node(1)
        rep = sched.drain(budget_symbols=0)       # clamps to 1, no div/0
        assert rep.repaired_stripes >= 1 and rep.drain_time_s > 0

    def test_unrecoverable_stripe_dropped_not_wedged(self):
        # a stripe below k surviving shares cannot be repaired; it must
        # be dropped (reported) instead of wedging the queue forever
        store, sched = self._wired(n_nodes=SPEC4.n, stripe_symbols=16)
        store.put("x", payload_bytes(100))
        for v in range(1, SPEC4.n - SPEC4.k + 2):  # n - k + 1 losses
            store.fail_node(v)
        rep = sched.drain_all()
        assert rep.unrecoverable == store.stat("x").n_stripes
        assert rep.repaired_stripes == 0
        assert sched.pending() == 0               # queue is clean again
        for v in range(1, SPEC4.n - SPEC4.k + 2):
            store.replace_node(v)                 # provision newcomers
        data = payload_bytes(50, seed=9)          # life goes on: re-put
        store.put("x", data)
        assert store.get("x") == data

    def test_default_budget_from_link_model(self):
        store, sched = self._wired()
        assert sched.budget_symbols_per_tick() == int(
            store.link.bandwidth_bps * sched.tick_s
            * sched.repair_bandwidth_fraction)

    def test_subscribes_to_cluster_simulator_events(self):
        # the same failure feed can drive the store scheduler: the store
        # node dies silently (no direct subscription), and the matching
        # fail event from a SIMULATOR scenario run is what lands the
        # lost stripes in the repair queue
        store = make_store(n_nodes=SPEC4.n, stripe_symbols=16)
        sched = RepairScheduler(store)
        data = payload_bytes(100)
        store.put("x", data)
        store.fail_node(3)                 # nothing subscribed yet
        assert sched.pending() == 0
        sim = ClusterSimulator(SPEC4, np.zeros((SPEC4.n, 8), np.int32))
        sim.subscribe(sched.on_event)
        seen = []
        sim.subscribe(lambda e: seen.append(e.kind))
        sim.run(single_node_loss(SPEC4.n, node=3, reads=2))
        assert "fail" in seen
        assert sched.pending() > 0         # node 3 stripes enqueued
        sched.drain_all()
        assert store.get("x") == data and store.verify()

    def test_replace_node_reprotects_lost_at_birth_shares(self):
        # shares skipped because their node was FAILED at put time never
        # produced a fail event; the newcomer's `up` event re-protects
        store, sched = self._wired(n_nodes=SPEC4.n, stripe_symbols=16)
        store.fail_node(3)
        data = payload_bytes(400)
        store.put("x", data)               # node 3's shares lost at birth
        assert store.total_lost_shares() > 0
        assert sched.pending() == 0        # no fail event covered these
        store.replace_node(3)
        assert sched.pending() > 0         # `up` event enqueued them
        sched.drain_all()
        assert store.total_lost_shares() == 0
        assert store.get("x") == data and store.verify()

    def test_drop_stale_entries_on_deleted_object(self):
        store, sched = self._wired(stripe_symbols=16)
        store.put("x", payload_bytes(400))
        store.fail_node(1)
        assert sched.pending() > 0
        store.delete("x")
        rep = sched.drain_all()
        assert rep.repaired_stripes == 0 and sched.pending() == 0


# --------------------------------------------------- store-backed checkpoints
class TestStoreBackedCheckpointer:
    def _state(self):
        return {"w": np.arange(600, dtype=np.float32).reshape(30, 20),
                "b": np.ones(11, np.float64), "step": np.int32(3)}

    def test_save_restore_roundtrip(self):
        store = make_store(stripe_symbols=128)
        ck = MSRCheckpointer(None, store=store, leaf_group_bytes=1024)
        state = self._state()
        ck.save(1, state)
        out, rep = ck.restore(state)
        for key in state:
            assert np.array_equal(out[key], state[key])
        assert rep.path == "store" and rep.bytes_read > 0
        assert rep.bytes_total_stored > 0

    def test_restore_through_failures_bit_exact(self):
        store = make_store(stripe_symbols=128)
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        ck = MSRCheckpointer(None, store=store)
        state = self._state()
        ck.save(1, state)
        store.fail_node(2)
        store.fail_node(7)
        out, rep = ck.restore(state)
        for key in state:
            assert np.array_equal(out[key], state[key])
        sched.drain_all()
        assert store.verify()

    def test_leaf_groups_and_gc(self):
        store = make_store(stripe_symbols=64)
        ck = MSRCheckpointer(None, store=store, keep_last=2,
                             leaf_group_bytes=1024)
        state = self._state()           # w alone is 2400 bytes > group size
        for s in (1, 2, 3):
            ck.save(s, state)
        assert ck.steps() == [2, 3]
        groups = [k for k in store.keys()
                  if k.startswith("ckpt/step_000003/g")]
        assert len(groups) >= 2         # leaves split across objects
        assert not any(k.startswith("ckpt/step_000001/")
                       for k in store.keys())

    def test_store_mode_guards(self):
        store = make_store()
        ck = MSRCheckpointer(None, store=store)
        ck.save(1, self._state())
        with pytest.raises(ValueError, match="no failed_nodes"):
            ck.restore(self._state(), failed_nodes=[1])
        with pytest.raises(RuntimeError, match="directory-mode only"):
            ck.scrub(1)
        with pytest.raises(RuntimeError, match="directory-mode only"):
            ck.repair_node(1, 2)

    def test_directory_mode_unchanged(self, tmp_path):
        ck = MSRCheckpointer(tmp_path, SPEC4)
        state = self._state()
        ck.save(1, state)
        out, rep = ck.restore(state, failed_nodes=[2])
        for key in state:
            assert np.array_equal(out[key], state[key])
        assert rep.path == "regenerate" and rep.bytes_read > 0


# --------------------------------------------------------- serve integration
def test_serving_engine_reads_param_pytree_from_store():
    from repro.serve.engine import _read_coded_params
    store = make_store(stripe_symbols=256)
    params = {"layer": {"w": np.full((8, 8), 3.0, np.float32),
                        "b": np.zeros(8, np.float32)}}
    store.put_pytree("params", params)
    out = _read_coded_params(store, "params")
    assert np.array_equal(out["layer"]["w"], params["layer"]["w"])
    store.fail_node(1)
    store.fail_node(6)
    out2 = _read_coded_params(store, "params")   # transparent degraded
    assert np.array_equal(out2["layer"]["w"], params["layer"]["w"])
    assert np.array_equal(out2["layer"]["b"], params["layer"]["b"])


# ------------------------------------ atomic put + audit (DESIGN.md §12.2)
class TestAtomicPut:
    """A put that dies mid-flight must be invisible: the old value (if
    any) stays readable, a new key never appears half-written."""

    def _faulty_store(self, match="node:03", times=None):
        from repro.io import FaultInjector, fast_retry
        faults = FaultInjector(seed=0)
        kw = {} if times is None else {"times": times}
        faults.add(op="write", match=match, kind="transient", **kw)
        # n_nodes == n so every stripe places a share on the faulted node
        store = make_store(spec=SPEC4, n_nodes=SPEC4.n, faults=faults,
                           retry=fast_retry(max_attempts=2))
        return store

    def test_failed_overwrite_keeps_old_value(self):
        from repro.io import GiveUpError
        store = self._faulty_store()
        store.faults.clear()                   # healthy while the first
        old = payload_bytes(3000, seed=1)      # generation lands...
        store.put("k", old)
        store.faults.add(op="write", match="node:03", kind="transient")
        with pytest.raises(GiveUpError):
            store.put("k", payload_bytes(3000, seed=2))
        assert store.get("k") == old           # old generation intact
        audit = store.audit()
        assert audit.clean and store.verify()

    def test_failed_new_key_put_is_invisible(self):
        from repro.io import GiveUpError
        store = self._faulty_store()
        with pytest.raises(GiveUpError):
            store.put("ghost", payload_bytes(2000))
        assert "ghost" not in store.keys()
        with pytest.raises(KeyError):
            store.get("ghost")
        assert store.audit().clean
        store.faults.clear()                   # disk healed: put succeeds
        data = payload_bytes(2000, seed=9)
        store.put("ghost", data)
        assert store.get("ghost") == data

    def test_transient_fault_heals_within_retry_budget(self):
        from repro.io import FaultInjector, fast_retry
        faults = FaultInjector(seed=0)
        faults.add(op="write", match="node:02", kind="transient", times=2)
        store = make_store(spec=SPEC4, n_nodes=SPEC4.n, faults=faults,
                           retry=fast_retry(max_attempts=4))
        data = payload_bytes(4000, seed=5)
        store.put("k", data)                   # retries absorb both faults
        assert store.get("k") == data
        assert store.retry_stats.giveups == 0
        assert store.retry_stats.retries >= 2

    def test_audit_flags_and_gc_collects_orphans(self):
        store = make_store()
        store.put("k", payload_bytes(3000))
        assert store.audit().clean
        # plant a ghost share: unknown key on some node
        store._shares[0][("zombie", 0)] = [1, np.zeros(64, np.int32),
                                           np.zeros(64, np.int32)]
        audit = store.audit()
        assert not audit.clean and not store.verify()
        (phys, key, t, reason) = audit.orphan_shares[0]
        assert (phys, key, t) == (1, "zombie", 0) and "unknown" in reason
        assert store.gc_orphans() == 1
        assert store.audit().clean and store.verify()

    def test_audit_flags_out_of_range_stripe(self):
        store = make_store()
        store.put("k", payload_bytes(1000))
        n_stripes = store._stats["k"].n_stripes
        store._shares[2][("k", n_stripes + 5)] = [3, np.zeros(64, np.int32),
                                                  np.zeros(64, np.int32)]
        audit = store.audit()
        assert [o[3] for o in audit.orphan_shares] == ["stripe out of range"]
        store.gc_orphans()
        assert store.audit().clean


# --------------------------------- scheduler restart recovery (§12.5)
class TestSchedulerRestart:
    def test_enqueue_scan_resumes_interrupted_drain(self):
        store = make_store(spec=SPEC4, n_nodes=8, stripe_symbols=16)
        for i in range(3):
            store.put(f"obj{i}", payload_bytes(2500, seed=i))
        sched = RepairScheduler(store)
        store.fail_node(2)
        sched.enqueue_node(2)
        sched.drain(budget_symbols=(SPEC4.k + 1) * 16)  # partial, then "crash"
        del sched
        fresh = RepairScheduler(store)          # restarted with empty queue
        assert fresh.enqueue_scan() > 0         # rebuilt from store metadata
        fresh.drain_all()
        assert store.verify()
        assert store.total_lost_shares() == 0

    def test_enqueue_scan_noop_when_healthy(self):
        store = make_store()
        store.put("k", payload_bytes(1000))
        sched = RepairScheduler(store)
        assert sched.enqueue_scan() == 0


# ------------------------------------------ share integrity (DESIGN.md §13.2)
class TestShareIntegrity:
    def test_unknown_key_typed_on_get_stat_delete(self):
        from repro.store import UnknownKeyError
        store = make_store()
        for op in (store.get, store.stat, store.delete):
            with pytest.raises(UnknownKeyError) as ei:
                op("ghost")
            assert ei.value.key == "ghost"
            assert isinstance(ei.value, KeyError)   # generic handlers work

    def test_put_records_crc_for_every_share(self):
        from repro.store import share_crc
        store = make_store()
        stat = store.put("a", payload_bytes(3000))
        assert len(stat.share_crcs) == stat.n_stripes
        for t in range(stat.n_stripes):
            assert len(stat.share_crcs[t]) == store.n
            pl = store.placement_of("a", t)
            for j in range(1, store.n + 1):
                share = store._shares[pl[j - 1] - 1][("a", t)]
                assert share_crc(share[1], share[2]) \
                    == stat.share_crcs[t][j - 1]

    def test_lost_at_birth_shares_still_get_crcs(self):
        store = make_store()
        store.fail_node(1)
        stat = store.put("a", payload_bytes(500))
        assert all(crc != 0 or True for row in stat.share_crcs
                   for crc in row)
        assert all(len(row) == store.n for row in stat.share_crcs)
        # the ledger covers the absent share: once rebuilt (repairs are
        # bit-exact) it verifies against the put-time CRC
        sched = RepairScheduler(store)
        sched.enqueue_scan()
        sched.drain_all()
        for t in range(stat.n_stripes):
            pl = store.placement_of("a", t)
            for j in range(1, store.n + 1):
                assert store.share_intact(pl[j - 1], "a", t) is True

    def test_share_intact_drop_and_scrub(self):
        store = make_store()
        store.put("a", payload_bytes(200, seed=1))
        pl = store.placement_of("a", 0)
        phys = pl[0]
        assert store.share_intact(phys, "a", 0) is True
        store._shares[phys - 1][("a", 0)][1][3] ^= 0x55
        assert store.share_intact(phys, "a", 0) is False
        assert store.scrub_node(phys) == [("a", 0)]
        assert store.drop_share(phys, "a", 0) is True
        assert store.share_intact(phys, "a", 0) is None     # absent now
        assert store.drop_share(phys, "a", 0) is False
        assert store.scrub_node(phys) == []

    def test_audit_flags_crc_mismatch_orphan_class(self):
        store = make_store()
        store.put("a", payload_bytes(200, seed=2))
        assert store.audit().clean
        phys = store.placement_of("a", 0)[0]
        store._shares[phys - 1][("a", 0)][1][0] ^= 0x55
        audit = store.audit()
        assert not audit.clean
        assert any(reason == "crc mismatch" and key == "a"
                   for _, key, _, reason in audit.orphan_shares)

    def test_degraded_get_refuses_rotten_helper(self):
        from repro.store import ShareIntegrityError
        store = make_store(spec=SPEC2, n_nodes=6)
        store.put("a", payload_bytes(100, seed=3))
        pl = store.placement_of("a", 0)
        store.fail_node(pl[0])                   # force the decode path
        # rot a helper the decode is guaranteed to pick: any-k uses the
        # first k present code nodes
        present = sorted(store.present_code_nodes("a", 0))
        victim = present[0]
        store._shares[pl[victim - 1] - 1][("a", 0)][1][0] ^= 0x55
        with pytest.raises(ShareIntegrityError) as ei:
            store.get("a")
        assert ei.value.key == "a" and ei.value.stripe == 0

    def test_repair_requeues_on_rotten_helper_then_recovers(self):
        store = make_store(spec=SPEC2, n_nodes=6)
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        data = payload_bytes(100, seed=4)
        store.put("a", data)
        pl = store.placement_of("a", 0)
        store.fail_node(pl[0])
        assert sched.pending() == 1
        # k=2: the embedded repair of the lost share uses every other
        # share as a helper, so any rot is in its helper set
        rot_phys = pl[1]
        store._shares[rot_phys - 1][("a", 0)][1][0] ^= 0x55
        rep = sched.drain(budget_symbols=10_000_000)
        assert rep.repaired_stripes == 0        # refused to decode garbage
        assert sched.pending() == 1             # requeued, not dropped
        store.drop_share(rot_phys, "a", 0)      # the scrub path's move
        sched.drain_all()
        assert sched.pending() == 0
        assert store.get("a") == data
        assert store.verify()

    def test_delete_event_purges_scheduler_queue(self):
        store = make_store()
        sched = RepairScheduler(store)
        store.subscribe(sched.on_event)
        store.put("a", payload_bytes(1500, seed=5))
        store.put("b", payload_bytes(1500, seed=6))
        store.fail_node(1)
        before = sched.pending()
        assert before > 0
        a_tasks = sum(1 for key, _, _ in sched.peek_order() if key == "a")
        assert a_tasks > 0
        store.delete("a")
        assert sched.pending() == before - a_tasks
        assert all(key != "a" for key, _, _ in sched.peek_order())
        sched.drain_all()
        assert store.get("b") == payload_bytes(1500, seed=6)
