"""GF(p) arithmetic: field axioms (hypothesis), exactness envelope, linalg."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import gf

PRIMES = [2, 3, 5, 7, 257]


@given(st.integers(0, 10_000), st.integers(0, 10_000), st.integers(0, 10_000),
       st.sampled_from(PRIMES))
@settings(max_examples=60, deadline=None)
def test_field_axioms(a, b, c, p):
    add, mul = gf.add, gf.mul
    assert int(add(add(a, b, p), c, p)) == int(add(a, add(b, c, p), p))
    assert int(mul(mul(a, b, p), c, p)) == int(mul(a, mul(b, c, p), p))
    assert int(mul(a, add(b, c, p), p)) == int(add(mul(a, b, p), mul(a, c, p), p))
    assert int(add(a, gf.neg(a, p), p)) == 0


@given(st.integers(1, 10_000), st.sampled_from(PRIMES))
@settings(max_examples=60, deadline=None)
def test_inverse(a, p):
    if a % p == 0:
        return
    assert int(gf.mul(a, gf.inv(a, p), p)) == 1


@given(st.integers(0, 2**31 - 1), st.integers(0, 40), st.sampled_from(PRIMES))
@settings(max_examples=40, deadline=None)
def test_pow_matches_python(x, e, p):
    assert int(gf.pow_(x, e, p)) == pow(x % p, e, p)


@pytest.mark.parametrize("p", [5, 257])
@pytest.mark.parametrize("shape", [(3, 4, 5), (8, 128, 16), (1, 300, 2), (130, 200, 64)])
def test_matmul_exact_vs_int64(p, shape):
    m, k, n = shape
    rng = np.random.default_rng(m * k * n + p)
    a = rng.integers(0, p, size=(m, k))
    b = rng.integers(0, p, size=(k, n))
    want = (a.astype(np.int64) @ b.astype(np.int64)) % p
    got = np.asarray(gf.matmul(jnp.asarray(a), jnp.asarray(b), p))
    np.testing.assert_array_equal(got, want)


def test_matmul_fold_boundary_worst_case():
    """All-(p-1) inputs at k just above the fold size must stay exact."""
    p = 257
    k = 300  # > _FOLD = 128 -> exercises the folded path with worst-case magnitudes
    a = np.full((4, k), p - 1)
    b = np.full((k, 8), p - 1)
    want = (a.astype(np.int64) @ b.astype(np.int64)) % p
    got = np.asarray(gf.matmul(jnp.asarray(a), jnp.asarray(b), p))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("p", [5, 257])
@pytest.mark.parametrize("n", [1, 2, 5, 16])
def test_gauss_inverse_roundtrip(p, n):
    rng = np.random.default_rng(n + p)
    for _ in range(5):
        m = rng.integers(0, p, size=(n, n))
        if gf.gauss_det(m, p) == 0:
            continue
        inv = gf.gauss_inverse(m, p)
        eye = (m.astype(np.int64) @ inv.astype(np.int64)) % p
        np.testing.assert_array_equal(eye, np.eye(n, dtype=np.int64) % p)


def test_gauss_inverse_singular_raises():
    m = np.array([[1, 2], [2, 4]])
    with pytest.raises(ValueError):
        gf.gauss_inverse(m, 5)


def test_gauss_det_multiplicative():
    p = 257
    rng = np.random.default_rng(0)
    a = rng.integers(0, p, size=(6, 6))
    b = rng.integers(0, p, size=(6, 6))
    da, db = gf.gauss_det(a, p), gf.gauss_det(b, p)
    dab = gf.gauss_det((a.astype(np.int64) @ b.astype(np.int64)) % p, p)
    assert dab == (da * db) % p


@given(st.binary(min_size=0, max_size=4096))
@settings(max_examples=30, deadline=None)
def test_bytes_symbols_roundtrip(payload):
    sym = gf.bytes_to_symbols(payload)
    assert gf.symbols_to_bytes(sym) == payload


def test_solve_matches_inverse():
    p = 257
    rng = np.random.default_rng(1)
    m = rng.integers(0, p, size=(8, 8))
    while gf.gauss_det(m, p) == 0:
        m = rng.integers(0, p, size=(8, 8))
    rhs = rng.integers(0, p, size=(8, 3))
    x = gf.solve(m, rhs, p)
    np.testing.assert_array_equal((m.astype(np.int64) @ x) % p, rhs % p)
